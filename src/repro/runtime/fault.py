"""Fault tolerance: step watchdog (straggler/hang detection) and the
checkpoint-restart training loop wrapper.

Cluster mapping (documented here, simulated in tests):
  * A *straggler* at pod scale shows up as step-time inflation; the watchdog
    tracks a robust (median-based) step-time estimate and flags steps that
    exceed ``threshold x`` the median — the launcher's response is to
    checkpoint + evict + restart on a spare slice (JAX's multi-controller
    runtime cannot drop a single host without re-initializing the mesh, so
    restart-from-checkpoint IS the mitigation; this matches how production
    TPU fleets handle it).
  * A *node failure* raises from the device runtime; ``resilient_loop``
    catches, restores from the last committed checkpoint, and replays.
    Determinism comes from the stateless step->batch mapping (data/pipeline),
    so a replayed step consumes identical data.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from statistics import median
from typing import Callable


@dataclass
class StepWatchdog:
    """Detects hung/straggling steps from host-observed step times."""

    threshold: float = 3.0          # x median
    window: int = 32
    min_samples: int = 5
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Record a step time; returns True if this step is a straggler."""
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) < self.min_samples:
            return False
        med = median(self.times)
        slow = dt > self.threshold * med
        if slow:
            self.flagged.append((step, dt, med))
        return slow


@dataclass
class LoopStats:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    stragglers: int = 0


def resilient_loop(*, num_steps: int, step_fn: Callable[[int, dict], dict],
                   state: dict, save_fn: Callable[[int, dict], None],
                   restore_fn: Callable[[], tuple[int, dict]],
                   checkpoint_every: int = 10, max_failures: int = 5,
                   watchdog: StepWatchdog | None = None,
                   start_step: int = 0) -> tuple[dict, LoopStats]:
    """Run ``step_fn(step, state) -> state`` with checkpoint/restart.

    On any exception: restore the last committed checkpoint and continue from
    its step. ``step_fn`` failures inject exactly like device faults in tests.
    """
    stats = LoopStats()
    wd = watchdog or StepWatchdog()
    step = start_step
    while step < num_steps:
        try:
            t0 = time.perf_counter()
            state = step_fn(step, state)
            dt = time.perf_counter() - t0
            if wd.observe(step, dt):
                stats.stragglers += 1
            stats.steps_run += 1
            step += 1
            if step % checkpoint_every == 0:
                save_fn(step, state)
        except Exception:
            stats.failures += 1
            if stats.failures > max_failures:
                raise
            step, state = restore_fn()
            stats.restores += 1
    save_fn(step, state)
    return state, stats
