"""Validate the analytic FLOPs model against XLA cost_analysis.

Strategy: build a *depth-reduced but width-faithful* config (2 layer-units,
full d_model/heads/ffn), lower the step WITHOUT scan-hiding (num_units small
=> the scan body ~ half the program; we instead compare per-layer deltas):

  cost(k units) - cost(k-1 units) ~= analytic per-unit FLOPs

This sidesteps both the scan-undercount and the fixed embedding/head cost.
Run on a single CPU device (sharding-free lowering).
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import transformer
from repro.roofline.analysis import _trunk_flops_per_token


def _unrolled_loss(cfg, params, batch):
    """trunk without lax.scan (layers unrolled) so cost_analysis sees all."""
    x = transformer.embed_inputs(cfg, params, batch["inputs"], batch["positions"])
    from repro.models import layers as L
    angles = L.positional_angles(cfg, batch["positions"])
    units = params["units"]
    for u in range(cfg.num_units):
        unit = jax.tree_util.tree_map(lambda t: t[u], units)
        for j, kind in enumerate(cfg.block_pattern):
            x = transformer.block_apply(cfg, kind, unit[f"b{j}_{kind}"], x, angles)
    for j, kind in enumerate(cfg.leftover_pattern):
        x = transformer.block_apply(cfg, kind, params["extra"][j], x, angles)
    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = (x @ transformer.lm_head(cfg, params)).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, batch["labels"][..., None], axis=-1)[..., 0]
    return (lse - ll).mean()


def _lowered_flops(cfg, batch_shape, seq):
    params = jax.eval_shape(
        lambda k: transformer.init_params(cfg, k), jax.random.PRNGKey(0))
    if cfg.frontend == "tokens":
        inputs = jax.ShapeDtypeStruct((batch_shape, seq), jnp.int32)
    else:
        inputs = jax.ShapeDtypeStruct((batch_shape, seq, cfg.d_model), jnp.float32)
    batch = {"inputs": inputs,
             "labels": jax.ShapeDtypeStruct((batch_shape, seq), jnp.int32),
             "positions": jax.ShapeDtypeStruct((batch_shape, seq), jnp.int32)}
    c = jax.jit(lambda p, b: _unrolled_loss(cfg, p, b)).lower(params, batch).compile()
    return float((c.cost_analysis() or {}).get("flops", 0.0))


def validate_arch(arch: str, *, seq: int = 128, batch: int = 2,
                  width_scale: int = 4) -> dict:
    """Returns analytic-vs-XLA per-unit forward FLOPs ratio for one arch."""
    base = get_config(arch)
    # width-reduced so CPU lowering is quick, but structurally faithful
    cfg = base.reduced(
        d_model=max(128, base.d_model // width_scale // 128 * 128) if base.d_model >= 512 else base.d_model,
        num_heads=max(2, base.num_heads // width_scale) if base.num_heads else 0,
        num_kv_heads=max(1, base.num_kv_heads // width_scale) if base.num_kv_heads else 0,
        head_dim=base.resolved_head_dim,
        d_ff=max(128, base.d_ff // width_scale),
        vocab_size=min(base.vocab_size, 8192),
        num_experts=base.num_experts, top_k=base.top_k,
        num_shared_experts=base.num_shared_experts,
        moe_d_ff=max(64, (base.moe_d_ff or base.d_ff) // width_scale)
        if base.num_experts else 0,
        window=min(base.window, seq) if base.window else 0,
        num_layers=len(base.block_pattern),
        mrope_sections=base.mrope_sections,   # head_dim stays full-width
        dtype="float32", q_chunk=64,
    )
    u = cfg.unit_len
    cfg1 = replace(cfg, num_layers=u)       # 1 unit
    cfg2 = replace(cfg, num_layers=2 * u)   # 2 units
    f1 = _lowered_flops(cfg1, batch, seq)
    f2 = _lowered_flops(cfg2, batch, seq)
    xla_unit = f2 - f1
    analytic_unit = batch * seq * _trunk_flops_per_token(cfg1, seq / 2, group_tokens=seq)
    return {"arch": arch, "xla_unit_flops": xla_unit,
            "analytic_unit_flops": analytic_unit,
            "ratio_analytic_over_xla": analytic_unit / max(xla_unit, 1.0)}
