"""Inject generated tables into EXPERIMENTS.md placeholders.

  PYTHONPATH=src python -m repro.roofline.inject
"""
from __future__ import annotations

from pathlib import Path

from repro.roofline.report import build_tables, load_records
from repro.roofline import validate


def main():
    recs = load_records("artifacts/dryrun")
    tables = build_tables(recs)

    # FLOPs-model validation table (re-run live)
    val_lines = ["## Appendix — FLOPs model validation (analytic vs XLA, "
                 "unrolled unit differencing)\n",
                 "| arch | analytic/XLA per-unit FLOPs |", "|---|---|"]
    for arch in ["yi-6b", "stablelm-3b", "qwen2.5-3b", "smollm-360m",
                 "musicgen-large", "qwen2-vl-7b", "recurrentgemma-9b",
                 "rwkv6-3b", "qwen2-moe-a2.7b", "llama4-maverick-400b-a17b"]:
        try:
            r = validate.validate_arch(arch)
            val_lines.append(f"| {arch} | {r['ratio_analytic_over_xla']:.3f} |")
        except Exception as e:  # noqa: BLE001
            val_lines.append(f"| {arch} | error: {str(e)[:60]} |")
    val_table = "\n".join(val_lines) + "\n"

    p = Path("EXPERIMENTS.md")
    text = p.read_text()
    text = text.replace("<!-- DRYRUN_TABLE -->",
                        tables.split("### Roofline table")[0].strip())
    text = text.replace("<!-- ROOFLINE_TABLE -->",
                        "### Roofline table" +
                        tables.split("### Roofline table", 1)[1].strip())
    text = text.replace("<!-- VALIDATION_TABLE -->", val_table)
    p.write_text(text)
    print(f"injected tables for {len(recs)} cells into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
