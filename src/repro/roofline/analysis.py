"""Roofline analysis for the dry-run cells (TPU v5e target).

CPU container => no wall-clock MFU; the three roofline terms are *derived*:

  compute term    = step FLOPs / (chips x 197 TFLOP/s bf16)
  memory term     = step HBM bytes / (chips x 819 GB/s)
  collective term = step wire bytes through a chip / 50 GB/s per link

FLOPs/bytes come from an analytic per-block model (below) because XLA's
``cost_analysis`` counts a ``lax.scan`` body once (verified empirically —
DESIGN.md §7), which silently undercounts layer-stacked and chunk-scanned
programs. The analytic model is validated against ``cost_analysis`` on an
*unrolled* small-depth lowering (``validate_flops_model``), and the dry-run's
parsed HLO collective inventory cross-checks which collectives the model
should be counting.

MODEL_FLOPS(6ND) is reported per cell along with MODEL/HLO — the fraction of
executed compute that is "useful," exposing remat and attention overheads.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class _HW:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip (v5e)
    hbm_bw: float = 819e9            # bytes/s per chip
    ici_bw: float = 50e9             # bytes/s per link (conservative 1 link)
    hbm_bytes: float = 16 * 2 ** 30  # capacity per chip


HW = _HW()

_P_BYTES = 2          # bf16 params
_A_BYTES = 2          # bf16 activations


# ---------------------------------------------------------------------------
# parameter counts
# ---------------------------------------------------------------------------

def _block_param_counts(cfg: ModelConfig, kind: str) -> tuple[float, float]:
    """(total_params, active_params) for one block of ``kind``."""
    d, f = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    fe = cfg.moe_d_ff or f
    if kind in ("attn", "attn_local", "moe"):
        attn = d * cfg.num_heads * hd + 2 * d * cfg.num_kv_heads * hd \
            + cfg.num_heads * hd * d
        if kind == "moe":
            routed = cfg.num_experts * 3 * d * fe
            shared = cfg.num_shared_experts * 3 * d * fe
            router = d * cfg.num_experts
            total = attn + routed + shared + router
            active = attn + cfg.top_k * 3 * d * fe + shared + router
            return total, active
        ffn = 3 * d * f
        return attn + ffn, attn + ffn
    if kind == "rec":
        rec = 5 * d * d + cfg.conv_width * d     # w_x, w_gate, w_out, w_r, w_i
        return rec + 3 * d * f, rec + 3 * d * f
    # rwkv: 5 tmix proj + out  + lora (small) + channel mix
    tmix = 5 * d * d + 2 * d * 32 * 6
    cmix = 2 * d * f + d * d
    return tmix + cmix, tmix + cmix


def param_counts(cfg: ModelConfig) -> tuple[float, float]:
    """(total, active) parameters including embeddings/head."""
    total = active = 0.0
    pattern = list(cfg.block_pattern) * cfg.num_units + list(cfg.leftover_pattern)
    for kind in pattern:
        t, a = _block_param_counts(cfg, kind)
        total += t
        active += a
    emb = cfg.vocab_size * cfg.d_model
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    return total + emb + head, active + emb + head


# ---------------------------------------------------------------------------
# FLOPs model
# ---------------------------------------------------------------------------

def _block_flops_per_token(cfg: ModelConfig, kind: str, ctx: float,
                           group_tokens: int = 0) -> float:
    """Executed forward FLOPs for one token through one block; ``ctx`` =
    attention context length (S/2 for causal training, cache length for
    decode). MoE counts all E*C capacity slots (capacity_factor slop executes
    whether or not a slot is filled — matches the slot-indexed dispatch)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    fe = cfg.moe_d_ff or cfg.d_ff
    _, active = _block_param_counts(cfg, kind)
    if kind == "moe":
        import math
        routed = cfg.top_k * 3 * d * fe
        if group_tokens:  # capacity rounds up per group (slot-indexed dispatch)
            c = max(1, math.ceil(cfg.capacity_factor * group_tokens * cfg.top_k
                                 / cfg.num_experts))
            eff_cf = cfg.num_experts * c / (group_tokens * cfg.top_k)
        else:
            eff_cf = cfg.capacity_factor
        active = active - routed + eff_cf * routed
    flops = 2.0 * active                        # every active param = 1 MAC/token
    if kind in ("attn", "attn_local", "moe"):
        eff_ctx = min(ctx, cfg.window) if (kind == "attn_local" and cfg.window) else ctx
        flops += 4.0 * cfg.num_heads * hd * eff_ctx   # QK^T + PV
    elif kind == "rwkv":
        flops += 6.0 * d * hd                    # state update + readout per head
    elif kind == "rec":
        flops += 12.0 * d                        # RG-LRU elementwise recurrence
    return flops


def _trunk_flops_per_token(cfg: ModelConfig, ctx: float,
                           group_tokens: int = 0) -> float:
    pattern = list(cfg.block_pattern) * cfg.num_units + list(cfg.leftover_pattern)
    return sum(_block_flops_per_token(cfg, k, ctx, group_tokens) for k in pattern)


def flops_model(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Step FLOPs (global) + MODEL_FLOPS (6·N_active·D) for the cell."""
    b, s = shape.global_batch, shape.seq_len
    total, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = b * s
        fwd = tokens * (_trunk_flops_per_token(cfg, s / 2, group_tokens=s)
                        + 2.0 * cfg.d_model * cfg.vocab_size)
        step = 4.0 * fwd                 # fwd + remat recompute + 2x bwd
        model = 6.0 * active * tokens
    elif shape.kind == "prefill":
        tokens = b * s
        step = tokens * _trunk_flops_per_token(cfg, s / 2, group_tokens=s) \
            + b * 2.0 * cfg.d_model * cfg.vocab_size
        model = 2.0 * active * tokens
    else:  # decode: one token against a seq_len context
        step = b * (_trunk_flops_per_token(cfg, s, group_tokens=1)
                    + 2.0 * cfg.d_model * cfg.vocab_size)
        model = 2.0 * active * b
    return {"step_flops": step, "model_flops": model,
            "useful_ratio": model / step}


# ---------------------------------------------------------------------------
# HBM traffic model (per chip)
# ---------------------------------------------------------------------------

def hbm_bytes_model(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                    accum: int = 1, moment_bytes: int = 4) -> float:
    """Mandatory HBM bytes per chip per step.

    train:  params read 3x (fwd + remat + bwd) x accum microbatches is wrong —
            weights stream once per microbatch: 3 reads per microbatch; plus
            optimizer read/write and gradient write; plus activation traffic.
    decode: params once + KV cache read/write (the classic decode wall).
    """
    total, _ = param_counts(cfg)
    p_loc = total * _P_BYTES / chips
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "train":
        tokens_loc = b * s / chips
        act = tokens_loc * d * _A_BYTES
        n_layers = cfg.num_layers
        param_traffic = p_loc * 3.0 * accum
        opt_traffic = (total / chips) * (2 * moment_bytes * 2 + 2 * _P_BYTES + 4)
        act_traffic = act * n_layers * 8.0       # r/w per block fwd+bwd+remat
        return param_traffic + opt_traffic + act_traffic
    if shape.kind == "prefill":
        tokens_loc = b * s / chips
        return p_loc + tokens_loc * d * _A_BYTES * cfg.num_layers * 4.0
    # decode
    cache_loc = _cache_bytes(cfg, shape) / chips
    return p_loc + cache_loc + b * d * _A_BYTES * cfg.num_layers / chips


def _cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    total = 0.0
    pattern = list(cfg.block_pattern) * cfg.num_units + list(cfg.leftover_pattern)
    for kind in pattern:
        if kind in ("attn", "moe"):
            total += 2 * b * s * cfg.num_kv_heads * hd * _A_BYTES
        elif kind == "attn_local":
            total += 2 * b * min(s, cfg.window) * cfg.num_kv_heads * hd * _A_BYTES
        elif kind == "rec":
            total += b * cfg.d_model * (cfg.conv_width) * _A_BYTES
        else:  # rwkv
            total += b * cfg.d_model * hd * 4    # fp32 wkv state
    return total


# ---------------------------------------------------------------------------
# collective traffic model (per chip, wire bytes)
# ---------------------------------------------------------------------------

def collective_bytes_model(cfg: ModelConfig, shape: ShapeConfig, *,
                           data: int = 16, model: int = 16, pods: int = 1,
                           accum: int = 1, grad_bytes: int = 4,
                           layout: str = "tp") -> dict:
    """Wire bytes per chip per step, by mechanism.

    layout="tp" (default): 2-D param sharding; 2 all-reduces per block over
          ``model`` per token; params sharded over ``data`` all-gathered per
          microbatch use (fwd + remat + bwd = 3x); grads reduce-scattered.
    layout="fsdp_only": batch shards over data x model jointly; NO tensor
          parallelism — every chip all-gathers the full weights 3x per step
          and reduce-scatters grads over all chips (overlappable with
          compute; the dominant term is latency-hidden in steady state).
    DP:   multi-pod gradient all-reduce over ``pods``.
    """
    total, _ = param_counts(cfg)
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    n_layers = cfg.num_layers
    chips = data * model * pods

    if shape.kind == "train":
        if layout == "fsdp_only":
            ways = data * model
            fsdp = 3.0 * accum * total * _P_BYTES * (ways - 1) / ways
            rs = total * grad_bytes * (ways - 1) / ways
            dp = (2.0 * total * grad_bytes / ways) * (pods - 1) / pods
            return {"fsdp_allgather": fsdp, "grad_reduce_scatter": rs,
                    "tp_allreduce": 0.0, "pod_allreduce": dp,
                    "total": fsdp + rs + dp}
        # per chip: params it must receive = total/model_shard minus own piece
        p_per_model_shard = total * _P_BYTES / model
        fsdp = 3.0 * accum * p_per_model_shard * (data - 1) / data
        rs = (total * grad_bytes / model) * (data - 1) / data
        tokens_loc = b * s / (data * pods)       # per model-column
        tp = 2 * n_layers * 2 * tokens_loc * d * _A_BYTES * 2 * (model - 1) / model
        dp = (2.0 * total * grad_bytes / (model * data)) * (pods - 1) / pods
        return {"fsdp_allgather": fsdp, "grad_reduce_scatter": rs,
                "tp_allreduce": tp, "pod_allreduce": dp,
                "total": fsdp + rs + tp + dp}
    if shape.kind == "prefill":
        p_per_model_shard = total * _P_BYTES / model
        fsdp = p_per_model_shard * (data - 1) / data
        tokens_loc = b * s / (data * pods) if b >= data * pods else b * s / pods
        tp = 2 * n_layers * tokens_loc * d * _A_BYTES * 2 * (model - 1) / model
        return {"fsdp_allgather": fsdp, "tp_allreduce": tp, "total": fsdp + tp}
    # decode: weights stay sharded over model only (no FSDP gather in the
    # steady state if params are replicated over data for serving); TP
    # all-reduces per layer + flash-decode LSE combine (negligible bytes)
    b_loc = max(b / (data * pods), 1)
    tp = 2 * n_layers * b_loc * d * _A_BYTES * 2 * (model - 1) / model
    lse = n_layers * b_loc * cfg.num_heads * 8 * 2   # max+sum scalars fp32
    return {"tp_allreduce": tp, "lse_combine": lse, "total": tp + lse}


# ---------------------------------------------------------------------------
# cell roofline
# ---------------------------------------------------------------------------

def cell_roofline(cfg: ModelConfig, shape: ShapeConfig, *, chips: int = 256,
                  data: int = 16, model: int = 16, pods: int = 1,
                  accum: int = 1, moment_bytes: int = 4,
                  layout: str = "tp") -> dict:
    fl = flops_model(cfg, shape)
    hbm = hbm_bytes_model(cfg, shape, chips, accum=accum,
                          moment_bytes=moment_bytes)
    coll = collective_bytes_model(cfg, shape, data=data, model=model,
                                  pods=pods, accum=accum, layout=layout)
    t_compute = fl["step_flops"] / (chips * HW.peak_flops)
    t_memory = hbm / HW.hbm_bw
    t_coll = coll["total"] / HW.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    t_bound = max(terms.values())
    return {
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "roofline_fraction": t_compute / t_bound if t_bound else 0.0,
        "step_flops": fl["step_flops"],
        "model_flops": fl["model_flops"],
        "useful_ratio": fl["useful_ratio"],
        "hbm_bytes_per_chip": hbm,
        "collective_bytes_per_chip": coll,
    }
