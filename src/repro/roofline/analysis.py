"""Analytic memory/compute roofline for the five Hippo Pallas kernels.

Hippo's hot phases (bitmap_and / batch_filter / bucketize / page_inspect /
compact_inspect) are elementwise scans and reductions: arithmetic intensity
is a handful of vector ops per byte, far below any accelerator's
compute/bandwidth ridge, so every one of them is memory-bound and the honest
performance statement is *achieved bytes/s as a fraction of the memory
roofline*. This module turns a timed run into that statement:

  cost = KERNELS["bitmap_and"](e=65536, w=13)     # analytic bytes + ops
  rl   = roofline(cost, seconds, hardware("cpu_stream"))
  rl["achieved_gbps"], rl["roofline_frac"], rl["bound"]

The bytes/ops models count *mandatory* main-memory traffic (every operand
read once, every output written once) and vector ops on the padded dense
shapes the kernels actually execute — no cache modeling. A ``roofline_frac``
above 1.0 therefore means the working set fit in cache (common for the
smaller CPU configs), not a broken clock; on TPU, where VMEM residency is
explicit, the model is the classic HBM roofline.

The hardware table carries the v5e numbers the kernel block shapes were
sized for plus a measured-STREAM entry for this CPU host, so CPU trajectory
files are gated against what the machine can actually sustain rather than
paper numbers.  ``hardware()`` with no argument picks by jax backend.
"""
from __future__ import annotations

import functools
import math
import time
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Hardware:
    """One row of the roofline hardware table.

    ``mem_bw`` is sustainable main-memory bandwidth in bytes/s (HBM for TPU,
    measured STREAM-copy for CPU); ``vector_ops`` is elementwise ops/s on
    the unit these kernels map to (VPU lanes for TPU, SIMD for CPU).
    """
    name: str
    mem_bw: float
    vector_ops: float
    note: str = ""

    @property
    def ridge_ai(self) -> float:
        """Ops/byte above which a kernel stops being memory-bound."""
        return self.vector_ops / self.mem_bw


# v5e per chip: 819 GB/s HBM; VPU = 8x128 lanes x ~4 ALUs x ~940 MHz ~= 3.9
# Tops/s elementwise (order-of-magnitude — these kernels sit at ~1 op/byte,
# ~5x under the ridge, so the memory term dominates regardless).
TPU_V5E = Hardware("tpu_v5e", mem_bw=819e9, vector_ops=3.9e12,
                   note="v5e chip: HBM 819 GB/s, VPU 8x128 lanes")


@functools.lru_cache(maxsize=None)
def measure_cpu_stream(mbytes: int = 64, reps: int = 5) -> float:
    """Measured STREAM-copy bandwidth of this host in bytes/s (min-time rep).

    A 64 MiB float64 copy defeats every cache level that matters; traffic is
    2 bytes moved per byte of array (read + write). Cached per process so
    benchmark loops pay the ~100 ms measurement once.
    """
    n = mbytes * 2**20 // 8
    src = np.full(n, 1.0)
    dst = np.empty_like(src)
    best = math.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return 2 * 8 * n / best


@functools.lru_cache(maxsize=None)
def _cpu_stream_hardware() -> Hardware:
    bw = measure_cpu_stream()
    # SIMD elementwise throughput estimate: ~4 lanes x 2 ports x ~3 GHz.
    # Like the VPU number it only decides the (never-reached) ridge.
    return Hardware("cpu_stream", mem_bw=bw, vector_ops=24e9 * 1.0,
                    note=f"measured STREAM copy {bw / 1e9:.1f} GB/s")


def hardware(name: str | None = None) -> Hardware:
    """Look up a hardware-table row; ``None`` detects by jax backend."""
    if name is None:
        import jax
        name = "tpu_v5e" if jax.default_backend() == "tpu" else "cpu_stream"
    if name == "tpu_v5e":
        return TPU_V5E
    if name == "cpu_stream":
        return _cpu_stream_hardware()
    raise KeyError(f"unknown hardware {name!r}; have: tpu_v5e, cpu_stream")


# ---------------------------------------------------------------------------
# per-kernel traffic/ops models
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KernelCost:
    """Mandatory main-memory bytes and elementwise vector ops for one call."""
    kernel: str
    bytes_moved: float
    ops: float

    @property
    def arithmetic_intensity(self) -> float:
        return self.ops / self.bytes_moved if self.bytes_moved else 0.0


def bitmap_and_cost(*, e: int, w: int) -> KernelCost:
    """§3.2 single-query filter: (E, W) u32 entries AND a (W,) u32 query,
    any-reduced to (E,) i32. Reads E*W words + the query, writes E flags."""
    bytes_moved = (e * w + w + e) * 4
    ops = 2.0 * e * w              # AND + nonzero/or-reduce per word
    return KernelCost("bitmap_and", bytes_moved, ops)


def batch_filter_cost(*, q: int, e: int, w: int, s: int = 1) -> KernelCost:
    """PR 1/2 fused batch filter: (Q, W) queries x (S, E, W) entries ->
    (S, Q, E) flags. Entries are read once per query (the (Q, E) grid
    re-streams the entry tile per query row)."""
    bytes_moved = (s * q * e * w + q * w + s * q * e) * 4
    ops = 3.0 * s * q * e * w      # AND + nonzero + or-reduce
    return KernelCost("batch_filter", bytes_moved, ops)


def bucketize_cost(*, n: int, h: int) -> KernelCost:
    """§4.2 bucket probe: N f32 values binary-searched into H buckets.
    Values in, ids out; the (H+1,) bounds table is VMEM/cache resident."""
    bytes_moved = (2 * n + (h + 1)) * 4
    ops = float(n) * math.ceil(math.log2(h + 1))
    return KernelCost("bucketize", bytes_moved, ops)


def page_inspect_cost(*, p: int, c: int) -> KernelCost:
    """§3.3 false-positive filter: (P, C) f32 keys + (P, C) bool validity
    under a (P,) page mask -> (P, C) qualifying bools + (P,) i32 counts."""
    bytes_moved = p * c * 4 + p * c + p + p * c + p * 4
    ops = 5.0 * p * c              # 2 cmps + 2 ands + count-reduce
    return KernelCost("page_inspect", bytes_moved, ops)


def compact_inspect_cost(*, q: int, m: int, c: int) -> KernelCost:
    """PR 4 gather-slab inspect: (M, C) f32 gathered keys + validity,
    (Q, M) selection mask, (Q,) bounds -> (Q, M) i32 counts. The slab is
    re-streamed per query row like batch_filter's entry tile."""
    bytes_moved = q * m * c * 4 + q * m * c + q * m + q * 8 + q * m * 4
    ops = 5.0 * q * m * c          # sel & valid & 2 cmps + count-reduce
    return KernelCost("compact_inspect", bytes_moved, ops)


KERNELS = {
    "bitmap_and": bitmap_and_cost,
    "batch_filter": batch_filter_cost,
    "bucketize": bucketize_cost,
    "page_inspect": page_inspect_cost,
    "compact_inspect": compact_inspect_cost,
}


# ---------------------------------------------------------------------------
# roofline statement
# ---------------------------------------------------------------------------

def roofline_from_traffic(bytes_moved: float, ops: float, seconds: float,
                          hw: Hardware) -> dict:
    """Roofline verdict for any (bytes, ops, time) triple on ``hw``.

    ``roofline_us`` is the analytic floor (slower of the memory and compute
    terms); ``roofline_frac`` = floor / measured — 1.0 means the run hit the
    roofline, >1.0 means the model's mandatory-traffic assumption was beaten
    (cache residency on CPU).
    """
    if seconds <= 0:
        raise ValueError(f"seconds must be positive, got {seconds}")
    t_mem = bytes_moved / hw.mem_bw
    t_ops = ops / hw.vector_ops
    t_roof = max(t_mem, t_ops)
    return {
        "hardware": hw.name,
        "bytes": float(bytes_moved),
        "ops": float(ops),
        "achieved_gbps": bytes_moved / seconds / 1e9,
        "roofline_gbps": hw.mem_bw / 1e9,
        "roofline_us": t_roof * 1e6,
        "roofline_frac": t_roof / seconds,
        "bound": "memory" if t_mem >= t_ops else "compute",
    }


def roofline(cost: KernelCost, seconds: float, hw: Hardware) -> dict:
    out = roofline_from_traffic(cost.bytes_moved, cost.ops, seconds, hw)
    out["kernel"] = cost.kernel
    return out
