"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables.

Reads artifacts/dryrun/*.json (compile proof, memory, HLO collective
inventory) and combines with the analytic roofline model (analysis.py).

  PYTHONPATH=src python -m repro.roofline.report [--dryrun-dir artifacts/dryrun]
      [--out artifacts/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.roofline.analysis import HW, cell_roofline, param_counts


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load_records(dryrun_dir: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(f"{dryrun_dir}/*.json")):
        recs.append(json.load(open(f)))
    return recs


def one_liner(cfg, shape, rl) -> str:
    """What would move the dominant term down (per-cell §Roofline note)."""
    b = rl["bottleneck"]
    if b == "compute":
        return "compute-bound: raise arithmetic efficiency (fusion, larger tiles)"
    if b == "memory":
        if shape.kind == "decode":
            return ("HBM-bound on weights+KV streaming: quantize KV (int8) or "
                    "raise batch to amortize weight reads")
        return "HBM-bound: fuse elementwise chains, cut remat re-reads"
    return ("ICI-bound: overlap collectives with compute, shrink TP degree "
            "or gradient compression")


def build_tables(recs: list[dict]) -> str:
    lines = []
    lines.append("### Dry-run table (compile proof, per-device memory)\n")
    lines.append("| arch | shape | mesh | accum | compile_s | args GiB | temp GiB "
                 "| TPU est GiB | fits 16GiB | collectives (HLO) |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        m = r["memory"]
        ops = ",".join(o.replace("all-", "a").replace("reduce-scatter", "rs")
                       .replace("collective-permute", "cp")
                       for o in r["collectives"]["ops"]) or "-"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('grad_accum',1)} "
            f"| {r['compile_s']} | {fmt_bytes(m['argument_bytes_per_device'])} "
            f"| {fmt_bytes(m['temp_bytes_per_device'])} "
            f"| {fmt_bytes(m['tpu_total_bytes_est'])} "
            f"| {'yes' if r['fits_hbm_16gib'] else 'NO'} | {ops} |")
    lines.append("")

    lines.append("### Roofline table (single-pod 16x16, analytic terms — "
                 "see methodology)\n")
    lines.append("| arch | shape | layout | compute | memory | collective | bottleneck "
                 "| roofline frac | MODEL_FLOPS | MODEL/HLO |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    singles = [r for r in recs if r["mesh"] == "16x16"]
    for r in singles:
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        rl = cell_roofline(cfg, shape, chips=256, data=16, model=16, pods=1,
                           accum=r.get("grad_accum", 1),
                           moment_bytes=2 if "400b" in r["arch"] else 4,
                           layout=r.get("layout", "tp"))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('layout','tp')} | {fmt_s(rl['compute_s'])} "
            f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
            f"| {rl['bottleneck']} | {rl['roofline_fraction']:.2f} "
            f"| {rl['model_flops']:.2e} | {rl['useful_ratio']:.2f} |")
    lines.append("")

    lines.append("### Per-cell bottleneck notes\n")
    for r in singles:
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        rl = cell_roofline(cfg, shape, chips=256, data=16, model=16, pods=1,
                           accum=r.get("grad_accum", 1),
                           layout=r.get("layout", "tp"))
        lines.append(f"- **{r['arch']} x {r['shape']}**: {rl['bottleneck']}-bound "
                     f"({one_liner(cfg, shape, rl)})")
    lines.append("")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts/roofline.md")
    args = ap.parse_args(argv)
    recs = load_records(args.dryrun_dir)
    text = build_tables(recs)
    Path(args.out).write_text(text)
    print(f"wrote {args.out} ({len(recs)} cells)")
    # quick console summary of worst cells
    singles = [r for r in recs if r["mesh"] == "16x16"]
    scored = []
    for r in singles:
        cfg = get_config(r["arch"])
        rl = cell_roofline(cfg, SHAPES[r["shape"]], chips=256,
                           accum=r.get("grad_accum", 1),
                           layout=r.get("layout", "tp"))
        scored.append((rl["roofline_fraction"], rl["bottleneck"],
                       r["arch"], r["shape"]))
    scored.sort()
    print("\nworst roofline fractions:")
    for fr, b, a, s in scored[:6]:
        print(f"  {fr:.3f}  {b:>10}  {a} x {s}")


if __name__ == "__main__":
    main()
