"""Print the per-kernel roofline table for a ``BENCH_*.json`` trajectory.

Reads the ``kernels`` suite rows (each carries its analytic ``bytes``/``ops``
derived fields from ``benchmarks/bench_kernels.py``) and restates them
against a hardware-table row — by default the one the run was gated against,
or any other with ``--hardware`` (e.g. project a CPU run onto v5e to see
what the same traffic would cost on HBM):

  PYTHONPATH=src python -m repro.roofline.report BENCH_2026-08-09.json
  PYTHONPATH=src python -m repro.roofline.report BENCH.json --hardware tpu_v5e
"""
from __future__ import annotations

import argparse
import json

from repro.roofline.analysis import hardware, roofline_from_traffic


def kernel_rows(doc: dict) -> list[dict]:
    """The kernels-suite rows of a trajectory document that carry the
    analytic traffic fields (bytes + ops) a roofline needs."""
    rows = doc.get("suites", {}).get("kernels", [])
    return [r for r in rows
            if {"bytes", "ops"} <= set(r.get("derived", {}))]


def build_table(doc: dict, hw_name: str | None = None) -> str:
    hw = hardware(hw_name)
    lines = [
        f"roofline vs {hw.name}: {hw.mem_bw / 1e9:.0f} GB/s mem, "
        f"{hw.vector_ops / 1e9:.0f} Gops/s vector ({hw.note})",
        f"{'kernel row':<34} {'us':>10} {'GB':>8} {'GB/s':>8} "
        f"{'roof us':>9} {'frac':>6}  bound",
    ]
    for row in kernel_rows(doc):
        d = row["derived"]
        us = row["us_per_call"]
        rl = roofline_from_traffic(d["bytes"], d["ops"], us / 1e6, hw)
        lines.append(
            f"{row['name']:<34} {us:>10.1f} {rl['bytes'] / 1e9:>8.4f} "
            f"{rl['achieved_gbps']:>8.1f} {rl['roofline_us']:>9.1f} "
            f"{rl['roofline_frac']:>6.2f}  {rl['bound']}")
    if len(lines) == 2:
        lines.append("  (no kernels-suite rows with bytes/ops fields — "
                     "rerun benchmarks.run with the kernels suite)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json", help="BENCH_*.json trajectory file")
    ap.add_argument("--hardware", default=None,
                    choices=("tpu_v5e", "cpu_stream"),
                    help="hardware-table row to restate against "
                         "(default: detect by jax backend)")
    args = ap.parse_args(argv)
    with open(args.bench_json) as f:
        doc = json.load(f)
    print(build_table(doc, args.hardware))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
