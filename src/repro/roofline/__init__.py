from repro.roofline.analysis import (  # noqa: F401
    KERNELS, Hardware, KernelCost, TPU_V5E, hardware, measure_cpu_stream,
    roofline, roofline_from_traffic,
)
