from repro.roofline.analysis import (  # noqa: F401
    HW, cell_roofline, flops_model, hbm_bytes_model, collective_bytes_model,
)
