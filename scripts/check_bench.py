"""Gate one benchmark trajectory file against another, offline.

The file-vs-file half of the regression gate (``benchmarks/run.py --check``
is the run-then-gate half; both share ``benchmarks/check.py``): compare a
fresh ``BENCH_*.json`` against the last committed one and fail when any
suite's ``qps`` or ``achieved_gbps`` dropped more than the tolerance —
20% by default, per-row overridable for known-noisy configs. Partial runs
(``--only``) gate only the suites they ran; vanished gated metrics fail.

  PYTHONPATH=src python scripts/check_bench.py BASELINE.json CURRENT.json \
      [--tolerance 0.2] [--row-tolerance drift_adaptive=0.5] [--quiet]

``--coverage`` instead audits a single trajectory as a would-be baseline,
``scripts/check_markers.py``-style: every suite registered in
``benchmarks/run.py`` must be present and emit at least one gated row, so
a new bench that never emits ``qps``/``achieved_gbps`` cannot dodge the
gate. Exit status: 0 clean, 1 regression/coverage gap, 2 malformed input.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks.check import (BaselineError, compare, coverage_problems,  # noqa: E402
                              delta_table, failures, load_trajectory,
                              parse_row_tolerances, DEFAULT_TOLERANCE)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_*.json to gate against")
    ap.add_argument("current", nargs="?", default=None,
                    help="fresh BENCH_*.json to check (omit with --coverage)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional drop per gated metric "
                         "(default %(default)s)")
    ap.add_argument("--row-tolerance", action="append", default=[],
                    metavar="ROW=FRAC",
                    help="per-row override (repeatable; bare row name or "
                         "suite/row)")
    ap.add_argument("--coverage", action="store_true",
                    help="audit BASELINE for gate coverage instead of "
                         "comparing: every registered suite must emit a "
                         "qps/achieved_gbps row")
    ap.add_argument("--quiet", action="store_true",
                    help="print only failing rows and the summary line")
    args = ap.parse_args(argv)

    try:
        doc = load_trajectory(args.baseline)
        if args.coverage:
            from benchmarks.run import SUITES
            problems = coverage_problems(doc, set(SUITES))
            for p in problems:
                print(p)
            if problems:
                return 1
            print(f"ok: {args.baseline} covers all {len(SUITES)} registered "
                  "suites with gated rows")
            return 0
        if args.current is None:
            ap.error("CURRENT is required unless --coverage is given")
        current = load_trajectory(args.current)
        row_tol = parse_row_tolerances(args.row_tolerance)
    except (BaselineError, ValueError) as e:
        print(e, file=sys.stderr)
        return 2

    deltas = compare(doc, current, tolerance=args.tolerance,
                     row_tolerance=row_tol)
    print(delta_table(deltas, verbose=not args.quiet))
    if failures(deltas):
        print(f"REGRESSION: {args.current} vs {args.baseline}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
