"""hippolint CLI — run the static invariant passes over the repo.

  python scripts/lint.py --all                 # every pass
  python scripts/lint.py locks crash           # a subset
  python scripts/lint.py --all --root <dir>    # another checkout

Exit 0 when the tree is clean (info-severity findings — the dead-seed
audit — are reported but never fail). Exit 1 with one
``path:line: [pass] message`` per finding otherwise. Suppress a
deliberate exception inline, justification mandatory::

    os.replace(d, tomb)  # hippolint: disable=crash -- <why this is safe>

Pass semantics and the annotation grammar are documented in
``docs/analysis.md``.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))

from repro.analysis import PASSES, load_context, run_passes  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("passes", nargs="*", metavar="pass",
                    help=f"passes to run (default: --all); "
                         f"one of: {', '.join(PASSES)}")
    ap.add_argument("--all", action="store_true",
                    help="run every registered pass")
    ap.add_argument("--root", type=pathlib.Path, default=REPO,
                    help="repo root to lint (default: this checkout)")
    args = ap.parse_args(argv)

    names = list(PASSES) if (args.all or not args.passes) else args.passes
    unknown = [n for n in names if n not in PASSES]
    if unknown:
        ap.error(f"unknown pass(es) {', '.join(unknown)}; "
                 f"known: {', '.join(PASSES)}")
    selected = {n: PASSES[n] for n in names}

    ctx = load_context(args.root.resolve())
    findings = run_passes(ctx, selected)
    errors = [f for f in findings if f.severity == "error"]
    for f in findings:
        print(f.render())
    scope = ", ".join(names)
    if errors:
        print(f"hippolint: {len(errors)} error finding(s) "
              f"({len(findings) - len(errors)} info) across [{scope}]")
        return 1
    print(f"hippolint: clean across [{scope}] "
          f"({len(findings)} info finding(s), {len(ctx.files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
