"""Fail when a test module uses a pytest marker the suite never declared.

The tiered test suite routes on markers (slow / shard / writer / compact /
drift, registered in ``tests/conftest.py``), and pytest only *warns* on an
unknown marker — so a typo'd or undeclared marker silently drops a module
out of every ``-m`` tier and the mistake rots. This checker walks every
``tests/*.py`` module's AST for ``pytest.mark.<name>`` uses (decorators,
``pytestmark`` assignments, ``pytest.param`` marks alike — anything spelled
``pytest.mark.X``) and compares them against the markers declared via
``config.addinivalue_line("markers", ...)`` in the conftest, plus pytest's
built-ins. Run standalone or through ``tests/test_markers.py``:

  python scripts/check_markers.py [tests_dir]

Exit status 1 lists every (file, marker) offender.
"""
from __future__ import annotations

import ast
import pathlib
import sys

# Markers pytest itself defines; always allowed.
BUILTIN_MARKERS = {
    "parametrize", "skip", "skipif", "xfail", "usefixtures",
    "filterwarnings", "tryfirst", "trylast",
}


def declared_markers(conftest_path: pathlib.Path) -> set[str]:
    """Markers registered via ``config.addinivalue_line("markers", "<name>:
    <description>")`` in a conftest, extracted from its AST."""
    tree = ast.parse(conftest_path.read_text())
    out: set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "addinivalue_line"
                and len(node.args) == 2
                and isinstance(node.args[0], ast.Constant)
                and node.args[0].value == "markers"
                and isinstance(node.args[1], ast.Constant)):
            decl = str(node.args[1].value)
            out.add(decl.split(":", 1)[0].strip().split("(", 1)[0].strip())
    return out


def used_markers(test_path: pathlib.Path) -> set[str]:
    """Every ``pytest.mark.<name>`` attribute chain in a module's AST."""
    tree = ast.parse(test_path.read_text())
    out: set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Attribute)
                and node.value.attr == "mark"
                and isinstance(node.value.value, ast.Name)
                and node.value.value.id == "pytest"):
            out.add(node.attr)
    return out


def find_offenders(tests_dir: pathlib.Path) -> list[tuple[str, str]]:
    """(file, marker) pairs for every undeclared, non-builtin marker use."""
    allowed = BUILTIN_MARKERS | declared_markers(tests_dir / "conftest.py")
    offenders = []
    for path in sorted(tests_dir.glob("*.py")):
        for marker in sorted(used_markers(path) - allowed):
            offenders.append((path.name, marker))
    return offenders


def main(argv: list[str] | None = None) -> int:
    args = sys.argv[1:] if argv is None else argv
    tests_dir = pathlib.Path(args[0]) if args else (
        pathlib.Path(__file__).resolve().parent.parent / "tests")
    offenders = find_offenders(tests_dir)
    for name, marker in offenders:
        print(f"{name}: marker {marker!r} is not declared in conftest.py "
              f"(register it in pytest_configure or fix the typo)")
    if offenders:
        return 1
    print(f"ok: every marker under {tests_dir} is declared")
    return 0


if __name__ == "__main__":
    sys.exit(main())
