"""Fail when a test module uses a pytest marker the suite never declared.

Thin wrapper: the implementation moved into ``repro.analysis.markers``
(the ``markers`` pass of hippolint — ``python scripts/lint.py markers``
runs the same check), and this entrypoint plus its public API
(``BUILTIN_MARKERS`` / ``declared_markers`` / ``used_markers`` /
``find_offenders`` / ``main``) stay put for CI and
``tests/test_markers.py``:

  python scripts/check_markers.py [tests_dir]

Exit status 1 lists every (file, marker) offender.
"""
from __future__ import annotations

import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parent.parent
if str(_REPO / "src") not in sys.path:
    sys.path.insert(0, str(_REPO / "src"))

from repro.analysis.markers import (BUILTIN_MARKERS,  # noqa: E402,F401
                                    declared_markers, find_offenders, main,
                                    used_markers)

if __name__ == "__main__":
    sys.exit(main())
