#!/usr/bin/env python
"""Merge N benchmark trajectory files into a conservative floor baseline.

On a shared host, a single sweep samples one noise mode — committing it as
the gate baseline means a lucky-fast run fails every honest run that
follows. This tool takes the element-wise *slowest* observation across N
sweeps (min of each gated metric, max ``us_per_call``), so ``--check``
fails only when a run drops below even the slowest committed mode by the
tolerance. Refresh recipe (see docs/benchmarks.md):

    for i in 1 2 3; do
        PYTHONPATH=src python -m benchmarks.run --quick --json /tmp/s$i.json
    done
    PYTHONPATH=src python scripts/merge_bench.py /tmp/s1.json /tmp/s2.json \
        /tmp/s3.json -o BENCH_$(date +%F)_prN_quick.json
"""
import argparse
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))

from benchmarks.check import GATED_FIELDS, load_trajectory  # noqa: E402


def merge(docs: list[dict]) -> dict:
    """Element-wise floor merge, keyed off the first document's rows."""
    first, rest = docs[0], docs[1:]
    out = {"schema": first["schema"], "config": dict(first["config"]),
           "suites": {}}
    out["config"]["merged_of"] = len(docs)
    if "generated_unix_s" in first:
        out["generated_unix_s"] = first["generated_unix_s"]
    for suite, rows in first["suites"].items():
        others = [{r["name"]: r for r in d["suites"].get(suite, [])}
                  for d in rest]
        merged_rows = []
        for row in rows:
            peers = [row] + [o[row["name"]] for o in others
                             if row["name"] in o]
            new = dict(row)
            new["derived"] = dict(row.get("derived") or {})
            us = [p["us_per_call"] for p in peers
                  if isinstance(p.get("us_per_call"), (int, float))]
            if us:
                new["us_per_call"] = max(us)
            for field in GATED_FIELDS:
                vals = [v for p in peers
                        for v in [(p.get("derived") or {}).get(field,
                                                               p.get(field))]
                        if isinstance(v, (int, float))
                        and not isinstance(v, bool)]
                if vals:
                    new["derived"][field] = min(vals)
                    if field in new:
                        new[field] = min(vals)
            merged_rows.append(new)
        out["suites"][suite] = merged_rows
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("inputs", nargs="+", help="trajectory JSON files")
    ap.add_argument("-o", "--output", required=True)
    args = ap.parse_args(argv)
    docs = [load_trajectory(p) for p in args.inputs]
    merged = merge(docs)
    with open(args.output, "w") as f:
        json.dump(merged, f, indent=1, allow_nan=False)
        f.write("\n")
    n_rows = sum(len(r) for r in merged["suites"].values())
    print(f"wrote {args.output}: floor of {len(docs)} runs, "
          f"{len(merged['suites'])} suites, {n_rows} rows")
    return 0


if __name__ == "__main__":
    sys.exit(main())
