"""End-to-end training driver example: train a ~100M-class model (reduced
smollm family) for a few hundred steps on CPU through the full stack —
Hippo-indexed data selection, AdamW, checkpointing, fault-tolerant loop.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is the (b) end-to-end driver deliverable: the same launch/train.py code
path that drives the production mesh runs here on the host device.
"""
import argparse

from repro.launch import train as train_driver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()

    losses = train_driver.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "16", "--seq", "64",
        "--lr", "3e-3",
        "--quality-min", "0.5",          # Hippo-index data selection predicate
        "--ckpt-dir", "/tmp/repro_example_ckpt",
        "--ckpt-every", "50",
    ])
    assert losses[-1] < losses[0], "loss must decrease"
    print(f"\nOK: loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
