"""Batched query serving: a stream of range predicates through QueryEngine.

    PYTHONPATH=src python examples/engine_serving.py

Simulates the multi-user serving scenario the engine exists for: a queue of
mixed-selectivity range queries is admitted into a fixed-slot batch and
executed one device program per batch (core.index.search_many), then the
same stream is replayed through the per-query loop to show the throughput
gap, then through a sharded index (core.partition) where the engine routes
each batch through per-shard summary bitmaps, then through the default
compact (gather) mode whose tickets also carry qualifying row ids, and
finally with writes mixed in: the async maintenance writer (runtime.writer)
stages inserts/deletes in per-shard queues and drains them between batches,
with staged rows overlaid into every count. Counts are asserted identical
between all paths.
"""
import time

import numpy as np

from repro.core.hippo import HippoIndex
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable


def main():
    rng = np.random.default_rng(0)
    card, page_card = 100_000, 50
    # Sorted keys: the time-ordered append workload (think order dates) where
    # page ranges correlate with value ranges — the case partition pruning
    # (and Hippo's page grouping itself) is built for.
    values = np.sort(rng.uniform(0, 1_000_000, card))
    table = PagedTable.from_values(values, page_card=page_card)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    print(f"table: {card:,} rows / {table.num_pages} pages; "
          f"index: {idx.num_entries} entries, {idx.nbytes():,} B")

    # A bursty stream: 200 queries of mixed selectivity.
    preds = []
    for _ in range(200):
        lo = float(rng.uniform(0, 1e6))
        preds.append(Predicate.between(lo, lo + float(rng.choice([200.0, 1e4, 1e5]))))

    engine = QueryEngine(idx, batch=64)
    engine.run_all(preds)   # warm the compiled traces + the adaptive bucket
    t0 = time.perf_counter()
    counts = engine.run_all(preds)
    dt_engine = time.perf_counter() - t0
    st = engine.stats
    print(f"engine:  {len(preds)} queries in {dt_engine*1e3:.1f} ms "
          f"({len(preds)/dt_engine:.0f} q/s) — {st.batches} batches, "
          f"occupancy {st.occupancy:.0%} "
          f"({st.slots_filled} real / {st.pad_slots} pad slots)")

    idx.search(preds[0])               # warm the scalar trace
    t0 = time.perf_counter()
    loop_counts = np.asarray([int(idx.search(p).count) for p in preds])
    dt_loop = time.perf_counter() - t0
    print(f"loop:    {len(preds)} queries in {dt_loop*1e3:.1f} ms "
          f"({len(preds)/dt_loop:.0f} q/s)")

    assert (counts == loop_counts).all(), "engine must be exact"
    print(f"counts identical across paths; engine speedup {dt_loop/dt_engine:.1f}x")

    # The same stream through a sharded partition layer with the routed
    # dense dispatch: the engine routes each batch through per-shard summary
    # bitmaps and reduces counts (mode="dense" + sharded=True).
    t2 = PagedTable.from_values(values, page_card=page_card)
    sidx = ShardedHippoIndex.create(t2, num_shards=4, resolution=400, density=0.2)
    sharded = QueryEngine(sidx, batch=64, sharded=True)
    # warm every dispatch-width trace the stream will use (steady state)
    QueryEngine(sidx, batch=64, sharded=True).run_all(preds)
    t0 = time.perf_counter()
    shard_counts = sharded.run_all(preds)
    dt_shard = time.perf_counter() - t0
    ss = sharded.stats
    occ = ", ".join(f"s{k}={v:.0%}" for k, v in ss.shard_occupancy().items())
    print(f"sharded: {len(preds)} queries in {dt_shard*1e3:.1f} ms "
          f"({len(preds)/dt_shard:.0f} q/s) — {ss.shard_dispatches} shard "
          f"dispatches, {ss.shards_pruned} pruned; occupancy {occ}")
    assert (shard_counts == loop_counts).all(), "sharded engine must be exact"

    # The default (compact) mode serves the same stream off the gathered
    # union-of-selected-pages slab — work proportional to what the batch
    # selects (see bench_selectivity_sweep for the workload where that wins
    # big; this broad mixed stream is its worst case and stays near parity).
    compact = QueryEngine(sidx, batch=64)
    compact.run_all(preds)                 # warm the traces + slab bucket
    t0 = time.perf_counter()
    compact_counts = compact.run_all(preds)
    dt_compact = time.perf_counter() - t0
    cs = compact.stats
    assert (compact_counts == loop_counts).all(), "compact engine must be exact"
    print(f"compact: {len(preds)} queries in {dt_compact*1e3:.1f} ms "
          f"({len(preds)/dt_compact:.0f} q/s) — selected-page ratio "
          f"{cs.selected_page_ratio:.0%}, gather occupancy "
          f"{cs.gather_occupancy:.0%}, {cs.compact_fallbacks} dense fallbacks")

    # With top_k set, tickets also carry qualifying global row ids.
    ids_engine = QueryEngine(sidx, batch=8, top_k=8)
    ticket = ids_engine.submit(preds[0])
    ids_engine.drain()
    vals = sidx.table.row_values(ticket.row_ids)
    lo, hi = ticket.pred.selectivity_interval()
    assert ((vals >= lo) & (vals <= hi)).all()
    print(f"compact: ticket qid={ticket.qid} carries {len(ticket.row_ids)} "
          f"row ids of its {ticket.count} matches, e.g. "
          f"{[int(i) for i in ticket.row_ids[:3]]} -> {np.round(vals[:3], 1)}")

    # Mixed read/write serving: writes go through the engine's async
    # maintenance writer instead of running Algorithm 3 on the query path.
    # engine.write() stages the row in its shard's pending queue (a host
    # list append); the default drain policy applies one shard queue as a
    # fused batch between query batches, and explicit flush() drains the
    # rest. Staged rows are overlaid into every count, so results are exact
    # at all times — asserted against a synchronous twin below.
    t3 = PagedTable.from_values(values, page_card=page_card, spare_pages=2048)
    widx = ShardedHippoIndex.create(t3, num_shards=4, resolution=400, density=0.2)
    wengine = QueryEngine(widx, batch=64)          # drain_policy="between_batches"
    t4 = PagedTable.from_values(values, page_card=page_card, spare_pages=2048)
    twin = ShardedHippoIndex.create(t4, num_shards=4, resolution=400, density=0.2)

    new_rows = rng.uniform(0, 1e6, 64)
    for v in new_rows:
        wengine.write(float(v))                    # staged, off the query path
        twin.insert(float(v))                      # synchronous twin
    ws = wengine.stats
    print(f"writer:  staged {ws.queue_depth} rows across "
          f"{len(wengine.writer.pending_shards())} shard queue(s) "
          f"(peak depth {ws.peak_queue_depth})")
    async_counts = wengine.run_all(preds)          # drains ride along batches
    twin_counts = np.asarray([twin.count(p) for p in preds])
    assert (async_counts == twin_counts).all(), \
        "staged counts must match the synchronous twin"
    wengine.delete(250_000, 260_000)               # validity mask now, vacuum queued
    t4.delete_where(250_000, 260_000)
    twin.vacuum()
    drained = wengine.flush()                      # apply everything pending now
    ws = wengine.stats
    print(f"writer:  drained {ws.drained_rows} rows in {ws.drains} units "
          f"({ws.drain_us/1e3:.1f} ms total); flush applied {drained} rows, "
          f"queue depth {ws.queue_depth}")
    after = wengine.run_all(preds)
    twin_after = np.asarray([twin.count(p) for p in preds])
    assert (after == twin_after).all(), "post-flush counts must match the twin"
    print("writer:  counts identical to the synchronous twin before and after "
          "the flush")


if __name__ == "__main__":
    main()
