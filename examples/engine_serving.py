"""Batched query serving: a stream of range predicates through QueryEngine.

    PYTHONPATH=src python examples/engine_serving.py

Simulates the multi-user serving scenario the engine exists for: a queue of
mixed-selectivity range queries is admitted into a fixed-slot batch and
executed one device program per batch (core.index.search_many), then the
same stream is replayed through the per-query loop to show the throughput
gap, and finally through a sharded index (core.partition) where the engine
routes each batch through per-shard summary bitmaps. Counts are asserted
identical between all paths.
"""
import time

import numpy as np

from repro.core.hippo import HippoIndex
from repro.core.partition import ShardedHippoIndex
from repro.core.predicate import Predicate
from repro.runtime.engine import QueryEngine
from repro.storage.table import PagedTable


def main():
    rng = np.random.default_rng(0)
    card, page_card = 100_000, 50
    # Sorted keys: the time-ordered append workload (think order dates) where
    # page ranges correlate with value ranges — the case partition pruning
    # (and Hippo's page grouping itself) is built for.
    values = np.sort(rng.uniform(0, 1_000_000, card))
    table = PagedTable.from_values(values, page_card=page_card)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    print(f"table: {card:,} rows / {table.num_pages} pages; "
          f"index: {idx.num_entries} entries, {idx.nbytes():,} B")

    # A bursty stream: 200 queries of mixed selectivity.
    preds = []
    for _ in range(200):
        lo = float(rng.uniform(0, 1e6))
        preds.append(Predicate.between(lo, lo + float(rng.choice([200.0, 1e4, 1e5]))))

    engine = QueryEngine(idx, batch=64)
    QueryEngine(idx, batch=64).run_all(preds[:1])   # warm the compiled trace
    t0 = time.perf_counter()
    counts = engine.run_all(preds)
    dt_engine = time.perf_counter() - t0
    st = engine.stats
    print(f"engine:  {len(preds)} queries in {dt_engine*1e3:.1f} ms "
          f"({len(preds)/dt_engine:.0f} q/s) — {st.batches} batches, "
          f"occupancy {st.occupancy:.0%} "
          f"({st.slots_filled} real / {st.pad_slots} pad slots)")

    idx.search(preds[0])               # warm the scalar trace
    t0 = time.perf_counter()
    loop_counts = np.asarray([int(idx.search(p).count) for p in preds])
    dt_loop = time.perf_counter() - t0
    print(f"loop:    {len(preds)} queries in {dt_loop*1e3:.1f} ms "
          f"({len(preds)/dt_loop:.0f} q/s)")

    assert (counts == loop_counts).all(), "engine must be exact"
    print(f"counts identical across paths; engine speedup {dt_loop/dt_engine:.1f}x")

    # The same stream through a sharded partition layer: the engine routes
    # each batch through per-shard summary bitmaps and reduces counts.
    t2 = PagedTable.from_values(values, page_card=page_card)
    sidx = ShardedHippoIndex.create(t2, num_shards=4, resolution=400, density=0.2)
    sharded = QueryEngine(sidx, batch=64)
    # warm every dispatch-width trace the stream will use (steady state)
    QueryEngine(sidx, batch=64).run_all(preds)
    t0 = time.perf_counter()
    shard_counts = sharded.run_all(preds)
    dt_shard = time.perf_counter() - t0
    ss = sharded.stats
    occ = ", ".join(f"s{k}={v:.0%}" for k, v in ss.shard_occupancy().items())
    print(f"sharded: {len(preds)} queries in {dt_shard*1e3:.1f} ms "
          f"({len(preds)/dt_shard:.0f} q/s) — {ss.shard_dispatches} shard "
          f"dispatches, {ss.shards_pruned} pruned; occupancy {occ}")
    assert (shard_counts == loop_counts).all(), "sharded engine must be exact"


if __name__ == "__main__":
    main()
