"""Quickstart: build a Hippo index, query it, maintain it.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's lifecycle end-to-end: CREATE INDEX (Algorithm 2 density
grouping), range/equality SELECTs (Algorithm 1 bitmap filtering), eager
INSERT (Algorithm 3), lazy DELETE + VACUUM (§5.2) — and prints the
storage/inspection numbers next to a B+-Tree and a BRIN-style min-max index.
"""
import numpy as np

from repro.core.baselines import BPlusTree, MinMaxIndex
from repro.core.hippo import HippoIndex
from repro.core.predicate import Predicate
from repro.storage.table import PagedTable


def main():
    rng = np.random.default_rng(0)
    card, page_card = 100_000, 50
    values = rng.uniform(0, 1_000_000, card)          # unordered attribute

    print("== CREATE INDEX hippo_idx ON t USING hippo(attr) ==")
    table = PagedTable.from_values(values, page_card=page_card, spare_pages=512)
    idx = HippoIndex.create(table, resolution=400, density=0.2)
    bt = BPlusTree.bulk_load(values, page_card)
    mm = MinMaxIndex.build(table.device_keys(), table.device_valid())
    print(f"  pages={table.num_pages}  hippo entries={idx.num_entries}")
    print(f"  sizes: hippo={idx.nbytes():,} B (rle {idx.nbytes(compressed=True):,}) "
          f"| b+tree={bt.nbytes():,} B ({bt.nbytes()/idx.nbytes():.1f}x) "
          f"| minmax={mm.nbytes():,} B")

    print("\n== SELECT * WHERE attr BETWEEN 500000 AND 501000 (SF~0.1%) ==")
    pred = Predicate.between(500_000, 501_000)
    res = idx.search(pred)
    _, mm_pages = mm.search(table.device_keys(), table.device_valid(),
                            500_000.0, 501_000.0)
    print(f"  hippo: {int(res.count)} rows, inspected "
          f"{int(res.pages_inspected)}/{table.num_pages} pages "
          f"({int(res.pages_inspected)/table.num_pages:.1%})")
    print(f"  minmax (unordered data): inspected {int(mm_pages)}/{table.num_pages} "
          f"pages ({int(mm_pages)/table.num_pages:.1%}) — the §8 failure mode")
    brute = int(((values >= 500_000) & (values <= 501_000)).sum())
    assert int(res.count) == brute, "Hippo must be exact"
    print(f"  exactness check vs brute force: OK ({brute} rows)")

    print("\n== INSERT (eager, Algorithm 3) ==")
    before = idx.num_entries
    for v in rng.uniform(0, 1_000_000, 200):
        idx.insert(float(v))
    res2 = idx.search(pred)
    print(f"  inserted 200 tuples; entries {before} -> {idx.num_entries}; "
          f"query still exact: {int(res2.count)} rows")

    print("\n== DELETE + VACUUM (lazy, §5.2) ==")
    n = table.delete_where(500_000, 501_000)
    res3 = idx.search(pred)     # correct BEFORE any index maintenance
    resum = idx.vacuum()
    res4 = idx.search(pred)
    print(f"  deleted {n} tuples; pre-vacuum count={int(res3.count)} (exact), "
          f"vacuum re-summarized {resum}/{idx.num_entries} entries, "
          f"post-vacuum count={int(res4.count)}")
    print(f"  pages inspected after vacuum: {int(res4.pages_inspected)} "
          f"(was {int(res3.pages_inspected)})")


if __name__ == "__main__":
    main()
