"""HippoKV (beyond-paper): the paper's bitmap machinery pruning KV-cache
pages for long-context decode.

    PYTHONPATH=src python examples/hippokv_longcontext.py

Builds Hippo-style page summaries over a synthetic clustered key cache and
shows the accuracy/pages-touched trade-off as the query-side bucket selection
widens — the exact analogue of the paper's density knob, applied to
attention. Exact attention stays the default in the framework; this is the
opt-in approximate mode (DESIGN.md §3).
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core.kvindex import (KVIndexConfig, build_kv_index,
                                hippo_kv_attention, query_page_mask)


def main():
    B, S, H, HD = 1, 4096, 8, 64
    key = jax.random.PRNGKey(0)
    kc, kn, kv, kq = jax.random.split(key, 4)
    # clustered keys: 64-token pages share topic centroids (prompt locality)
    centers = jax.random.normal(kc, (S // 64, 1, H, HD))
    keys = jnp.repeat(centers, 64, axis=0).reshape(S, 1, H, HD).transpose(1, 0, 2, 3)
    keys = keys + 0.3 * jax.random.normal(kn, (1, S, H, HD))
    values = jax.random.normal(kv, (1, S, H, HD))
    q = jax.random.normal(kq, (B, H, HD))

    cfg = KVIndexConfig(page_size=64, num_channels=8, resolution=16,
                        keep_buckets=4)
    idx = build_kv_index(cfg, keys)
    cache_mb = keys.size * 2 / 2**20
    print(f"cache: {S} positions, {cache_mb:.1f} MiB (bf16); "
          f"index: {idx.nbytes()/2**10:.1f} KiB "
          f"({idx.nbytes()/(keys.size*2):.1%} of cache)")

    full_pages = jnp.ones((B, H, S // 64), bool)
    ref, _ = hippo_kv_attention(q, keys, values, full_pages, 64)

    print(f"\n{'vote':>4} {'pages kept':>10} {'softmax mass':>12} {'rel err':>8}")
    for vote in (1, 2, 3, 4, 5):
        mask = query_page_mask(idx, q, min_channels=vote)
        out, mass = hippo_kv_attention(q, keys, values, mask, 64)
        rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
        print(f"{vote:4d} {float(mask.mean()):10.1%} "
              f"{float(mass.mean()):12.3f} {rel:8.3f}")
    print("\nexact attention remains the default; HippoKV is the opt-in "
          "approximate mode for attention-bearing archs.")


if __name__ == "__main__":
    main()
