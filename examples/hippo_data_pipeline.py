"""Hippo as the training data plane: predicate-filtered corpus selection.

    PYTHONPATH=src python examples/hippo_data_pipeline.py

Shows the paper's index doing real work inside an LM input pipeline: the
quality-range predicate runs Algorithm 1 over page summaries of the corpus
metadata, prunes most pages, and returns the exact qualifying sequence set;
batches then stream deterministically (restart-safe step->batch mapping).
"""
import numpy as np

from repro.core.predicate import Predicate
from repro.data import HippoDataPipeline, synthesize_corpus


def main():
    corpus = synthesize_corpus(num_seqs=20_000, seq_len=65, vocab_size=1024,
                               page_card=64, seed=0)
    for lo, hi in [(0.0, 1.0), (0.5, 1.0), (0.75, 1.0), (0.9, 1.0)]:
        pipe = HippoDataPipeline.create(corpus, Predicate.between(lo, hi))
        sel = pipe.selected_ids.size
        print(f"quality in [{lo:.2f}, {hi:.2f}]: {sel:6d}/{corpus.num_seqs} seqs, "
              f"inspected {pipe.pages_inspected}/{corpus.table.num_pages} pages "
              f"({pipe.pages_inspected/corpus.table.num_pages:.0%})")
        want = np.flatnonzero((corpus.quality >= lo) & (corpus.quality <= hi))
        assert np.array_equal(np.sort(pipe.selected_ids), want), "must be exact"

    pipe = HippoDataPipeline.create(corpus, Predicate.between(0.75, 1.0), seed=3)
    a = pipe.get_batch(42, 8)
    b = pipe.get_batch(42, 8)
    assert np.array_equal(a["inputs"], b["inputs"])
    print("\ndeterministic step->batch mapping: OK (restart-safe)")
    doms = corpus.domain[pipe.batch_ids(42, 256)]
    print(f"batch domain mix under quality>=0.75 predicate: "
          f"{np.bincount(doms, minlength=4).tolist()} (only domain 3 qualifies)")


if __name__ == "__main__":
    main()
