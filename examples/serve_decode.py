"""Batched-request serving example: continuous batching with slot recycling
against a prefill + lock-step decode loop (reduced smollm config).

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch import serve as serve_driver


def main():
    finished = serve_driver.main([
        "--arch", "smollm-360m", "--reduced",
        "--requests", "8", "--batch", "4",
        "--prompt-len", "16", "--gen", "24",
    ])
    assert len(finished) == 8
    assert all(len(r.generated) >= 24 for r in finished)
    print("OK: all requests served")


if __name__ == "__main__":
    main()
